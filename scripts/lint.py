#!/usr/bin/env python
"""Offline fallback linter: the scripts/check.sh lint step when ruff is not
installed (this container has no package index access).

Implements exactly the ruleset pyproject.toml selects for ruff — keep the
two in sync:

    E9    syntax errors (via compile())
    E501  line longer than 100 characters
    E711  comparison to None with == / !=
    E712  comparison to True / False with == / !=
    F401  imported but unused (module scope; honors `# noqa`, `__all__`
          re-export, and `import x as x` explicit re-export idioms)
    F811  redefinition of a top-level def/class in the same scope
    W291/W293  trailing whitespace

    python scripts/lint.py [paths...]      # default: src tests benchmarks scripts examples

Exit 0 when clean, 1 with findings (one `path:line: CODE message` per line).
"""
from __future__ import annotations

import ast
import os
import sys

MAX_LINE = 100
DEFAULT_ROOTS = ("src", "tests", "benchmarks", "scripts", "examples")


def iter_py_files(roots):
    for root in roots:
        if os.path.isfile(root) and root.endswith(".py"):
            yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def _noqa_lines(source: str) -> set[int]:
    return {i for i, line in enumerate(source.splitlines(), 1)
            if "# noqa" in line}


class _Usage(ast.NodeVisitor):
    """Collect every Name/Attribute-root identifier used outside imports."""

    def __init__(self):
        self.used: set[str] = set()

    def visit_Import(self, node):     # do not count the import itself
        pass

    def visit_ImportFrom(self, node):
        pass

    def visit_Name(self, node):
        self.used.add(node.id)

    def visit_Attribute(self, node):
        self.generic_visit(node)


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    problems = []
    lines = source.splitlines()
    noqa = _noqa_lines(source)

    for i, line in enumerate(lines, 1):
        if i in noqa:
            continue
        if len(line) > MAX_LINE:
            problems.append(f"{path}:{i}: E501 line too long "
                            f"({len(line)} > {MAX_LINE})")
        if line != line.rstrip():
            code = "W293" if not line.strip() else "W291"
            problems.append(f"{path}:{i}: {code} trailing whitespace")

    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        problems.append(f"{path}:{e.lineno}: E9 syntax error: {e.msg}")
        return problems

    # E711/E712
    for node in ast.walk(tree):
        if isinstance(node, ast.Compare) and node.lineno not in noqa:
            for op, comp in zip(node.ops, node.comparators):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                if isinstance(comp, ast.Constant):
                    if comp.value is None:
                        problems.append(
                            f"{path}:{node.lineno}: E711 comparison to None "
                            f"(use `is`/`is not`)")
                    elif comp.value is True or comp.value is False:
                        problems.append(
                            f"{path}:{node.lineno}: E712 comparison to "
                            f"{comp.value} (use `is` or truthiness)")

    # F401: module-scope imports never referenced
    exported = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" and \
                        isinstance(node.value, (ast.List, ast.Tuple)):
                    exported |= {e.value for e in node.value.elts
                                 if isinstance(e, ast.Constant)}
    usage = _Usage()
    usage.visit(tree)
    used = usage.used | {
        n for node in ast.walk(tree) if isinstance(node, ast.Attribute)
        for n in _attr_root(node)
    }
    for node in tree.body:
        if not isinstance(node, (ast.Import, ast.ImportFrom)):
            continue
        if node.lineno in noqa:
            continue
        if isinstance(node, ast.ImportFrom) and node.module == "__future__":
            continue
        for alias in node.names:
            name = alias.asname or alias.name.split(".")[0]
            if alias.name == "*":
                continue
            if alias.asname == alias.name:      # explicit re-export idiom
                continue
            if name in exported or name in used:
                continue
            problems.append(f"{path}:{node.lineno}: F401 `{alias.name}` "
                            f"imported but unused")

    # F811: duplicate top-level def/class names
    seen: dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name in seen and node.lineno not in noqa:
                problems.append(
                    f"{path}:{node.lineno}: F811 redefinition of "
                    f"`{node.name}` (first at line {seen[node.name]})")
            seen[node.name] = node.lineno
    return problems


def _attr_root(node: ast.Attribute):
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        yield node.id


def main() -> int:
    roots = sys.argv[1:] or [r for r in DEFAULT_ROOTS if os.path.exists(r)]
    problems = []
    n_files = 0
    for path in iter_py_files(roots):
        n_files += 1
        problems.extend(check_file(path))
    for p in problems:
        print(p)
    print(f"lint: {n_files} files, {len(problems)} problem(s)",
          file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
